"""Observability tests: typed metrics registry (atomic snapshot, ONE unified
reset), lock-free span recorder, exporters (JSONL contract + Chrome trace),
zero-cost-when-disabled guarantees, and end-to-end span trees over real TCP
for the interesting request fates (miss, cache hit, partial tile hit, dedup,
shed)."""
import asyncio
import json
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro.core.config import GSConfig
from repro.frontend import (
    AsyncFrontendClient,
    FrontendClient,
    Gateway,
    GatewayThread,
    SessionManager,
    ShedError,
)
from repro.obs import (
    NULL_RECORDER,
    STAGES,
    TRAIN_STAGES,
    MetricsRegistry,
    Obs,
    Span,
    TraceRecorder,
    new_request_id,
    spans_to_chrome,
    spans_to_jsonl,
    trace_meta,
    validate_trace_jsonl,
    write_trace,
)
from repro.obs.export import LANE_STRIDE
from repro.serve_gs import RenderServer

from conftest import make_cam, make_scene

H = W = 32


# ================================================================= registry
def test_counter_gauge_and_registry_are_idempotent_and_typed():
    m = MetricsRegistry()
    c = m.counter("tier.count")
    c.inc()
    c.add(2.5)  # float increments: wall-time sums are counters too
    assert c.value == 3.5
    assert m.counter("tier.count") is c  # idempotent re-registration
    g = m.gauge("tier.depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3
    with pytest.raises(TypeError, match="already registered as Counter"):
        m.histogram("tier.count")
    assert m.get("tier.count") is c and m.get("missing") is None
    assert m.names() == ["tier.count", "tier.depth"]


def test_histogram_percentiles_and_snapshot_shape():
    m = MetricsRegistry()
    h = m.histogram("t.lat_ms")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.mean == pytest.approx(50.5)
    assert h.vmin == 1.0 and h.vmax == 100.0
    p50, p95, p99 = h.percentile(50), h.percentile(95), h.percentile(99)
    assert 1.0 <= p50 <= p95 <= p99 <= 100.0
    assert p50 < 75.0  # interpolation keeps the median in the right half
    snap = h.snapshot()
    for key in ("count", "sum", "mean", "min", "max", "p50", "p95", "p99", "buckets"):
        assert key in snap
    assert snap["count"] == 100 and sum(snap["buckets"].values()) == 100
    # overflow: a sample beyond the last bound lands in the "inf" bucket
    h.observe(1e9)
    assert h.snapshot()["buckets"]["inf"] == 1


def test_registry_snapshot_is_sorted_and_reset_clears_everything():
    m = MetricsRegistry()
    m.counter("b.two").inc(7)
    m.counter("a.one").inc(3)
    m.histogram("c.three").observe(1.0)
    ran = []
    m.on_reset(lambda: ran.append(m.counter("a.one").value))  # hooks may read
    snap = m.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["a.one"] == 3 and snap["b.two"] == 7
    m.reset()
    assert ran == [0]  # hook ran under the lock, after the zeroing
    snap2 = m.snapshot()
    assert snap2["a.one"] == 0 and snap2["b.two"] == 0
    assert snap2["c.three"]["count"] == 0 and snap2["c.three"]["min"] is None


def test_registry_is_thread_safe_under_contention():
    m = MetricsRegistry()
    c = m.counter("x.n")
    h = m.histogram("x.h")

    def work():
        for i in range(1000):
            c.inc()
            h.observe(float(i % 7))

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000 and h.count == 8000


# ================================================================= recorder
def test_trace_recorder_orders_spans_and_counts_ring_drops():
    rec = TraceRecorder(capacity=4)
    assert rec  # truthy: instrumentation sites fire
    for i in range(6):
        rec.record(rid=i, name="render", t0=float(i), t1=float(i) + 0.5, batch=i)
    assert rec.recorded == 6 and rec.dropped == 2
    got = rec.spans()  # non-destructive
    assert [s.rid for s in got] == [2, 3, 4, 5]  # oldest two lapped
    assert got[0].dur == pytest.approx(0.5) and got[0].meta == {"batch": 2}
    drained = rec.drain()
    assert [s.rid for s in drained] == [2, 3, 4, 5]
    assert rec.spans() == [] and rec.dropped == 2  # accounting survives drain
    rec.instant(99, "admit", seq=0)
    (s,) = rec.spans()
    assert s.t0 == s.t1 and s.name == "admit"


def test_trace_recorder_multithreaded_writers_lose_nothing():
    rec = TraceRecorder(capacity=4096)

    def work(tid):
        for i in range(500):
            rec.record(rid=tid * 1000 + i, name="write", t0=0.0, t1=1.0)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert rec.recorded == 2000 and rec.dropped == 0
    spans = rec.spans()
    assert len(spans) == 2000
    assert [s.seq for s in spans] == sorted(s.seq for s in spans)


def test_null_recorder_is_falsy_noop_and_request_ids_are_monotonic():
    assert not NULL_RECORDER
    NULL_RECORDER.record(1, "render", 0.0, 1.0)
    NULL_RECORDER.instant(1, "admit")
    assert NULL_RECORDER.spans() == [] and NULL_RECORDER.drain() == []
    assert NULL_RECORDER.recorded == 0 and NULL_RECORDER.dropped == 0
    a, b = new_request_id(), new_request_id()
    assert 0 < a < b
    obs = Obs()
    assert obs.trace is NULL_RECORDER and not obs.tracing
    rec = obs.enable_trace(capacity=16)
    assert obs.tracing and obs.enable_trace() is rec  # idempotent
    obs.disable_trace()
    assert obs.trace is NULL_RECORDER


# ================================================================ exporters
def test_exporters_jsonl_contract_and_chrome_lanes(tmp_path):
    rec = TraceRecorder()
    rec.record(1, "admit", 10.0, 10.0, seq=0, stream="static")
    rec.record(2, "mystery_stage", 10.2, 10.4)  # unknown -> overflow lane
    spans = rec.spans()
    # a meta dict can't spoof the reserved keys (record() kwargs can never
    # collide with them, but a hand-built span could): the exporter skips them
    spans.insert(1, Span(0, 1, "render", 10.1, 10.3, {"batch": 2, "rid": "spoof"}))

    text = spans_to_jsonl(spans)
    assert validate_trace_jsonl(text) == 3
    lines = [json.loads(x) for x in text.splitlines()]
    assert lines[0] == {"rid": 1, "span": "admit", "t0": 10.0, "t1": 10.0,
                        "seq": 0, "stream": "static"}
    assert lines[1]["rid"] == 1 and lines[1]["batch"] == 2  # meta can't spoof rid

    chrome = spans_to_chrome(spans)
    events = chrome["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    # one named lane per pipeline stage, plus the overflow lane the unknown
    # stage landed on
    assert len(meta) == len(STAGES) + 1
    xs = {e["name"]: e for e in events if e["ph"] == "X"}
    assert xs["render"]["tid"] == (STAGES.index("render") + 1) * LANE_STRIDE
    # overflow sits past the serving AND training lane blocks
    assert xs["mystery_stage"]["tid"] == (len(STAGES) + len(TRAIN_STAGES) + 1) * LANE_STRIDE
    assert xs["admit"]["ts"] == 0.0  # rebased to the earliest span
    assert xs["render"]["dur"] == pytest.approx(0.2e6, rel=1e-3)
    assert chrome["otherData"]["clock_domain"] == "monotonic"

    jsonl_path, chrome_path = write_trace(str(tmp_path / "t.jsonl"), spans)
    assert chrome_path.endswith(".chrome.json")
    assert validate_trace_jsonl(open(jsonl_path).read()) == 3
    assert json.load(open(chrome_path))["traceEvents"]

    for bad, msg in [
        ('{"rid": -1, "span": "a", "t0": 0, "t1": 1}', "bad rid"),
        ('{"rid": 1, "t0": 0, "t1": 1}', "missing 'span'"),
        ('{"rid": 1, "span": "a", "t0": 2, "t1": 1}', "t1 < t0"),
        ("not json", "not JSON"),
        ('[1, 2]', "not an object"),
    ]:
        with pytest.raises(ValueError, match=msg):
            validate_trace_jsonl(bad + "\n")
    assert validate_trace_jsonl("") == 0


def test_chrome_overlapping_spans_spill_into_sublanes_and_meta_rides_along():
    """Two render spans that overlap in time (a pipelined wave) must land on
    DIFFERENT sub-lanes of the render block — they used to interleave into
    one unreadable bar row — and the export header (drop accounting + knobs)
    must survive both export formats."""
    rec = TraceRecorder(capacity=2)
    for i in range(3):  # capacity 2: the first span is lapped
        rec.record(i, "render", 1.0 + 0.1 * i, 1.25 + 0.1 * i, batch=4)
    spans = rec.spans()
    meta = trace_meta(rec, knobs={"max_batch": 4})
    assert meta["dropped"] == 1 and meta["capacity"] == 2

    chrome = spans_to_chrome(spans, meta=meta)
    xs = [e for e in chrome["traceEvents"] if e["ph"] == "X"]
    base = (STAGES.index("render") + 1) * LANE_STRIDE
    assert sorted(e["tid"] for e in xs) == [base, base + 1]  # overlap: 2 lanes
    labels = {e["args"]["name"] for e in chrome["traceEvents"] if e["ph"] == "M"}
    assert any(lbl.endswith("render#1") for lbl in labels)
    assert chrome["otherData"]["knobs"] == {"max_batch": 4}
    assert chrome["otherData"]["dropped"] == 1

    n = validate_trace_jsonl(spans_to_jsonl(spans, meta=meta))
    assert n == 2  # the meta line is not a span
    assert n.dropped == 1 and n.capacity == 2 and n.knobs == {"max_batch": 4}
    # meta anywhere but the first line is corruption, not data
    bad = spans_to_jsonl(spans) + '{"trace_meta": {}}\n'
    with pytest.raises(ValueError, match="first line"):
        validate_trace_jsonl(bad)


# ===================================================== zero-cost-when-off
def test_tracing_disabled_allocates_nothing_and_frames_are_bitwise():
    """The two acceptance guarantees of the no-op recorder: with tracing off
    a render allocates NOTHING in the recorder module, and enabling tracing
    changes no pixel of the rendered frame."""
    srv = RenderServer(
        make_scene(n=128, scale=0.06), GSConfig(img_h=H, img_w=W, k_per_tile=64),
        n_levels=1, max_batch=2, store_frames=False,
    )
    with srv:
        assert srv.obs.trace is NULL_RECORDER
        cam = make_cam(H, W, dist=2.3)
        srv.submit(cam).result()  # compile + warm every code path
        srv.cache.drop(lambda k: True)

        tracemalloc.start()
        s1 = tracemalloc.take_snapshot()
        frame_off = srv.submit(cam).result()
        s2 = tracemalloc.take_snapshot()
        tracemalloc.stop()
        filt = [tracemalloc.Filter(True, "*obs/trace*")]
        diff = s2.filter_traces(filt).compare_to(s1.filter_traces(filt), "lineno")
        assert sum(abs(d.size_diff) for d in diff) == 0, diff

        srv.obs.enable_trace()
        srv.cache.drop(lambda k: True)
        frame_on = srv.submit(cam).result()
        np.testing.assert_array_equal(np.asarray(frame_off), np.asarray(frame_on))
        spans = srv.obs.trace.drain()
        assert {s.name for s in spans} >= {"submit", "render"}
        assert all(s.name in STAGES for s in spans)


# ========================================================== span trees (TCP)
def _obs_manager(*, queue_limit=8, timeline_steps=2):
    g = make_scene(n=256, scale=0.06)
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    mgr = SessionManager(
        cfg, obs=Obs(trace=True), n_levels=1, max_batch=4,
        store_frames=False, pipeline_depth=2,
    )
    mgr.register_static("static", g)
    if timeline_steps:
        from repro.launch.frontend import synthetic_timeline

        mgr.register_timeline("timeline", synthetic_timeline(g, timeline_steps))
    return mgr


@pytest.fixture(scope="module")
def traced_gt():
    mgr = _obs_manager()
    mgr.warmup()
    with GatewayThread(Gateway(mgr, port=0, queue_limit=8)) as gt:
        yield gt


def _trees(spans) -> dict:
    """{rid: [spans in record order]}"""
    trees = {}
    for s in spans:
        trees.setdefault(s.rid, []).append(s)
    for v in trees.values():
        v.sort(key=lambda s: s.seq)
    return trees


def _wait_spans(rec, pred, timeout=30.0):
    """The write span lands on the gateway loop a beat after the client has
    its frame — poll (non-destructively) until the tree is complete."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        spans = rec.spans()
        if pred(spans):
            return spans
        time.sleep(0.01)
    return rec.spans()


def _named(tree, name):
    return [s for s in tree if s.name == name]


def test_tcp_miss_then_cache_hit_span_trees(traced_gt):
    """One TCP request -> ONE complete span tree, admit through socket write;
    a repeated pose yields the short cache-hit tree with no render span."""
    rec = traced_gt.gateway.obs.trace
    rec.drain()
    cam = make_cam(H, W, dist=2.45)
    with FrontendClient("127.0.0.1", traced_gt.port) as cl:
        cl.render("static", cam)
        cl.render("static", cam)
        spans = _wait_spans(
            rec, lambda ss: sum(1 for s in ss if s.name == "write") >= 2
        )
    trees = _trees(spans)
    assert len(trees) == 2
    rid_miss, rid_hit = sorted(trees)

    miss = trees[rid_miss]
    assert [s.name for s in miss] == [
        "admit", "coalesce", "submit", "render", "retire", "encode", "write",
    ]
    (sub,) = _named(miss, "submit")
    assert sub.meta["outcome"] == "miss"
    (adm,) = _named(miss, "admit")
    assert adm.meta["stream"] == "static" and adm.t0 == adm.t1  # instant root
    (ren,) = _named(miss, "render")
    assert ren.meta["batch"] >= 1 and ren.dur > 0
    (wr,) = _named(miss, "write")
    assert wr.meta["ok"] and wr.meta["bytes"] > 0
    for s in miss:
        assert s.t1 >= s.t0

    hit = trees[rid_hit]
    assert [s.name for s in hit] == ["admit", "coalesce", "submit", "encode", "write"]
    (sub,) = _named(hit, "submit")
    assert sub.meta["outcome"] in ("full_hit", "cache_hit")
    assert not _named(hit, "render")

    # the exported forms carry the full trees
    text = spans_to_jsonl(spans)
    assert validate_trace_jsonl(text) == len(spans)
    rids = {json.loads(x)["rid"] for x in text.splitlines()}
    assert rids == {rid_miss, rid_hit}


def test_tcp_dedup_span_points_at_primary_request(traced_gt):
    """Two identical poses coalescing into one wave: the second request's
    submit span reports outcome=dedup and names the primary request id —
    and only the primary carries the render span."""
    gw = traced_gt.gateway
    rec = gw.obs.trace
    rec.drain()
    cam = make_cam(H, W, dist=2.61)

    async def run():
        cl = AsyncFrontendClient("127.0.0.1", traced_gt.port)
        await cl.connect()
        traced_gt.call_soon(gw.pause)  # hold dispatch: both land in one wave
        await asyncio.sleep(0.05)
        futs = [await cl.submit_render("static", cam) for _ in range(2)]
        traced_gt.call_soon(gw.resume)
        frames = [await f for f in futs]
        await cl.close()
        return frames

    frames = asyncio.run(run())
    np.testing.assert_array_equal(frames[0], frames[1])
    spans = _wait_spans(
        rec, lambda ss: sum(1 for s in ss if s.name == "write") >= 2
    )
    trees = _trees(spans)
    assert len(trees) == 2
    rid_primary, rid_dup = sorted(trees)
    (sub,) = _named(trees[rid_dup], "submit")
    assert sub.meta["outcome"] == "dedup" and sub.meta["primary"] == rid_primary
    assert not _named(trees[rid_dup], "render")
    assert len(_named(trees[rid_primary], "render")) == 1
    assert _named(trees[rid_dup], "write") and _named(trees[rid_primary], "write")


def test_tcp_partial_tile_hit_span_tree(traced_gt):
    """Row-invalidation then a revisit: the submit span reports partial_hit
    with the missing-tile count, and the tree shows the strip render +
    assemble instead of a full-batch render."""
    gw = traced_gt.gateway
    rec = gw.obs.trace
    cam = make_cam(H, W, dist=2.77)
    with FrontendClient("127.0.0.1", traced_gt.port) as cl:
        cl.render("timeline", cam, timestep=1)  # fill the tile cache
        # drop ONLY tile row 0 of that timestep, on the engine thread
        n = gw.run_on_engine(
            lambda: gw.manager.invalidate("timeline", 1, rows=[0])
        ).result(timeout=60)
        assert n > 0
        rec.drain()
        cl.render("timeline", cam, timestep=1)
        spans = _wait_spans(
            rec, lambda ss: sum(1 for s in ss if s.name == "write") >= 1
        )
    (tree,) = _trees(spans).values()
    names = [s.name for s in tree]
    assert names == [
        "admit", "coalesce", "submit", "render", "assemble", "encode", "write",
    ]
    (sub,) = _named(tree, "submit")
    assert sub.meta["outcome"] == "partial_hit"
    assert sub.meta["missing_tiles"] == W // 16  # one 16px tile row
    (ren,) = _named(tree, "render")
    assert ren.meta["partial"] is True and ren.meta["rows"] == 1


def test_tcp_shed_request_emits_terminated_span():
    """A load-shed request's tree must END visibly: admit then a terminated
    shed span — and no render/write spans ever join that rid."""
    mgr = _obs_manager(timeline_steps=0)
    mgr.warmup()
    gw = Gateway(mgr, port=0, queue_limit=2)
    rec = mgr.obs.trace
    with GatewayThread(gw) as gt:

        async def run():
            cl = AsyncFrontendClient("127.0.0.1", gt.port)
            await cl.connect()
            gt.call_soon(gw.pause)
            await asyncio.sleep(0.05)
            futs = [
                await cl.submit_render("static", make_cam(H, W, dist=2.0 + 0.3 * i))
                for i in range(6)
            ]
            for fut in futs[:4]:
                with pytest.raises(ShedError):
                    await fut
            gt.call_soon(gw.resume)
            survivors = [await fut for fut in futs[4:]]
            await cl.close()
            return survivors

        survivors = asyncio.run(run())
        assert len(survivors) == 2
        spans = _wait_spans(
            rec, lambda ss: sum(1 for s in ss if s.name == "write") >= 2
        )
    trees = _trees(spans)
    shed_rids = {s.rid for s in spans if s.name == "shed"}
    assert len(shed_rids) == 4
    for rid in shed_rids:
        names = [s.name for s in trees[rid]]
        assert names == ["admit", "shed"]  # the tree ends here, visibly
        (sh,) = _named(trees[rid], "shed")
        assert sh.meta["terminated"] is True and sh.t1 >= sh.t0
    for rid in set(trees) - shed_rids:
        assert [s.name for s in trees[rid]] == [
            "admit", "coalesce", "submit", "render", "retire", "encode", "write",
        ]


# ===================================================== metrics on the wire
def test_metrics_message_round_trip_and_unified_reset_windows(traced_gt):
    """Protocol-v2 `metrics`: an atomic flat snapshot over TCP; ONE reset()
    zeroes every tier's counters (the benchmark-window contract) while the
    cache CONTENTS survive — the regression that motivated the unified
    reset: per-tier resets used to leave other tiers' windows dirty."""
    gw = traced_gt.gateway
    cam = make_cam(H, W, dist=2.93)
    with FrontendClient("127.0.0.1", traced_gt.port) as cl:
        cl.render("static", cam)
        out = cl.metrics()
        snap = out["metrics"]
        assert out["trace"]["enabled"] is True
        assert out["trace"]["recorded"] >= 1 and out["trace"]["dropped"] == 0
        assert snap["gateway.frames_sent"] >= 1
        assert snap["server.completed"] >= 1
        assert snap["sessions.admitted"] >= 1
        assert snap["cache.misses"] >= 1
        assert snap["server.latency_ms"]["count"] >= 1  # histograms ride along

        gw.run_on_engine(gw.manager.obs.metrics.reset).result(timeout=60)
        # NOT asserted zero: gateway.bytes_out — the deferred-drain write of
        # the previous reply may land (and count its bytes) after the reset
        snap2 = cl.metrics()["metrics"]
        for name in (
            "gateway.frames_sent", "gateway.shed",
            "server.completed", "server.deduped", "server.render_calls",
            "sessions.admitted", "cache.hits", "cache.misses",
        ):
            assert snap2[name] == 0, (name, snap2[name])
        assert snap2["server.latency_ms"]["count"] == 0

        # the new window starts clean AND warm: the same pose is still a
        # cache hit (reset clears counters, never cached content)
        cl.render("static", cam)
        snap3 = cl.metrics()["metrics"]
    assert snap3["gateway.frames_sent"] == 1
    assert snap3["server.completed"] == 1
    assert snap3["server.render_calls"] == 0  # no re-render happened
    assert snap3["server.full_hits"] == 1


def test_slo_state_is_visible_over_the_wire():
    """A gateway started with an SLO target must surface the tracker's state
    in BOTH wire surfaces: the protocol-v2 `metrics` message and the `stats`
    report — a real-TCP regression for the ops loop (dashboards watch the
    metrics message, humans read stats)."""
    mgr = _obs_manager(timeline_steps=0)
    mgr.warmup()
    gw = Gateway(mgr, port=0, queue_limit=8,
                 slo={"p99_ms": 2000.0, "window_s": 60.0})
    with GatewayThread(gw) as gt:
        with FrontendClient("127.0.0.1", gt.port) as cl:
            for i in range(3):
                cl.render("static", make_cam(H, W, dist=2.2 + 0.2 * i))
            slo = cl.metrics()["slo"]
            assert slo["state"] == "ok"  # 2s budget: smoke renders can't breach
            assert slo["target_p99_ms"] == 2000.0
            assert slo["window_count"] >= 1
            assert slo["window_p99_ms"] is not None
            stats = cl.stats()
    assert stats["gateway"]["slo"]["state"] == "ok"
    assert stats["gateway"]["slo"]["burn"] == 0.0
