"""Hypothesis property tests on the rendering system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import gaussians as G
from repro.core import projection as P
from repro.core import render as R
from repro.kernels.tile_raster.ref import compose_tile

from conftest import make_cam, make_scene


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 128),
    seed=st.integers(0, 10_000),
    opac=st.floats(-3.0, 3.0),
)
def test_transmittance_and_range(n, seed, opac):
    """0 <= T <= 1; colors in [0, 1] when splat colors are; more opacity
    never increases transmittance."""
    g = make_scene(n, seed=seed)
    g = g._replace(opacity_logit=jnp.full((n,), opac, jnp.float32))
    cam = make_cam(32, 32)
    img, t = R.render(g, cam, img_h=32, img_w=32, tile_h=16, tile_w=16, k_per_tile=128)
    t = np.asarray(t)
    img = np.asarray(img)
    assert np.all(t >= -1e-6) and np.all(t <= 1 + 1e-6)
    assert np.all(img >= -1e-5) and np.all(img <= 1 + 1e-5)

    g2 = g._replace(opacity_logit=g.opacity_logit + 1.0)
    _, t2 = R.render(g2, cam, img_h=32, img_w=32, tile_h=16, tile_w=16, k_per_tile=128)
    assert np.all(np.asarray(t2) <= t + 1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([16, 64, 256]))
def test_compose_permutation_of_padding_invariant(seed, k):
    """Invalid (masked) splats never affect the composite."""
    r = np.random.default_rng(seed)
    n_valid = r.integers(1, k)
    splats = r.normal(0, 1, (k, 11)).astype(np.float32)
    splats[:, P.OP] = r.uniform(0, 0.9, k)
    splats[:, P.CA] = r.uniform(0.1, 2, k)
    splats[:, P.CC] = r.uniform(0.1, 2, k)
    splats[:, P.CB] = 0.0
    splats[:, P.MX] = r.uniform(0, 16, k)
    splats[:, P.MY] = r.uniform(0, 16, k)
    valid = np.arange(k) < n_valid
    px = np.arange(16, dtype=np.float32) + 0.5
    py = np.zeros(16, dtype=np.float32) + 0.5
    bg = jnp.zeros(3)
    out1, t1 = compose_tile(jnp.asarray(splats), jnp.asarray(valid), jnp.asarray(px), jnp.asarray(py), bg)
    # scramble the masked-out tail
    splats2 = splats.copy()
    splats2[n_valid:] = r.normal(0, 10, (k - n_valid, 11))
    out2, t2 = compose_tile(jnp.asarray(splats2), jnp.asarray(valid), jnp.asarray(px), jnp.asarray(py), bg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_tile_lists_cover_naive(seed):
    """Tiled render with K >= N equals the naive oracle (tile binning loses
    nothing)."""
    n = 100
    g = make_scene(n, seed=seed)
    cam = make_cam(32, 64)
    packed = P.project(g, cam)
    ps, _ = P.sort_by_depth(packed)
    img_t, _ = R.render_packed(ps, img_h=32, img_w=64, tile_h=16, tile_w=16, k_per_tile=128)
    from repro.kernels.tile_raster.ref import rasterize_naive

    img_n, _ = rasterize_naive(ps, 32, 64, jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(img_t), np.asarray(img_n), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), depth_scale=st.floats(0.5, 2.0))
def test_projection_depth_ordering(seed, depth_scale):
    """Gaussians behind the camera are marked dead; depths are positive for
    visible ones."""
    g = make_scene(64, seed=seed, spread=depth_scale * 2)
    cam = make_cam(32, 32, dist=1.0)
    packed = np.asarray(P.project(g, cam))
    valid = packed[:, P.RAD] > 0
    assert np.all(packed[valid, P.DEPTH] > 0)
    assert np.all(packed[~valid, P.OP] == 0)
