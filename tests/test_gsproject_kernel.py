"""gsproject Pallas kernel vs the production-projection oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gsproject.ops import project_packed

from conftest import make_cam, make_scene

SWEEP = [(64, 32, 32), (700, 64, 64), (1500, 48, 96), (1024, 64, 64)]


@pytest.mark.parametrize("n,h,w", SWEEP)
def test_forward_allclose(n, h, w):
    g = make_scene(n, seed=n)
    cam = make_cam(h, w)
    ref = np.asarray(project_packed(g, cam, backend="ref"))
    pal = np.asarray(project_packed(g, cam, backend="pallas"))
    finite = np.isfinite(ref)
    assert (np.isfinite(pal) == finite).all()  # inf depth pattern identical
    np.testing.assert_allclose(pal[finite], ref[finite], atol=2e-5, rtol=2e-5)


def test_grad_matches_ref():
    g = make_scene(300, seed=1)
    cam = make_cam(32, 32)

    def loss(gm, backend):
        p = project_packed(gm, cam, backend=backend)
        p = jnp.where(jnp.isfinite(p), p, 0.0)
        return jnp.sum(jnp.sin(p))  # bounded cotangents

    gr = jax.grad(lambda m: loss(m, "ref"))(g)
    gp = jax.grad(lambda m: loss(m, "pallas"))(g)
    for name, a, b in zip(g._fields, gr, gp):
        a, b = np.asarray(a), np.asarray(b)
        scale = max(np.abs(a).max(), 1e-6)
        np.testing.assert_allclose(b, a, atol=2e-4 * scale, rtol=2e-3, err_msg=name)


def test_nonzero_sh_falls_back():
    g = make_scene(64, seed=2)
    g = g._replace(sh=jnp.zeros((64, 4, 3)))
    cam = make_cam(32, 32)
    out = project_packed(g, cam, backend="pallas")  # silently uses ref path
    assert out.shape == (64, 11)
