"""Streaming subsystem tests: dead-slot reseeding, temporal checkpoint store,
warm-start-vs-cold step counts (with zero re-traces), and time-scrub serving.
"""
import jax
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import gaussians as G
from repro.core.config import GSConfig
from repro.core.train import init_state
from repro.insitu import (
    InsituTrainer,
    TemporalCheckpointStore,
    build_timeline_server,
    fixed_capacity_init,
    reseed_dead_slots,
    scrub,
)
from repro.serve_gs import RenderServer
from repro.volume.timevary import miranda_growth

from conftest import make_cam

H = W = 32


def _random_params(n, seed=0, shift=0.0):
    r = np.random.default_rng(seed)
    g = G.init_from_points(
        jnp.asarray(r.normal(0, 0.4, (n, 3)).astype(np.float32) + shift),
        jnp.asarray(r.uniform(0.2, 0.8, (n, 3)).astype(np.float32)),
        init_scale=0.06,
    )
    return g


# ------------------------------------------------------------------ reseed
def test_fixed_capacity_init_pads_with_dead_slots():
    pts = np.random.default_rng(0).normal(0, 0.4, (10, 3)).astype(np.float32)
    cols = np.full((10, 3), 0.5, np.float32)
    g = fixed_capacity_init(pts, cols, 16)
    assert g.n == 16
    opac = 1.0 / (1.0 + np.exp(-np.asarray(g.opacity_logit)))
    assert (opac[:10] > 0.05).all() and (opac[10:] < 1e-6).all()
    np.testing.assert_allclose(np.asarray(g.means)[:10], pts)


def test_reseed_dead_slots_fills_only_dead_capacity():
    rng = np.random.default_rng(1)
    pts0 = rng.normal(0, 0.4, (12, 3)).astype(np.float32)
    state = init_state(fixed_capacity_init(pts0, np.full((12, 3), 0.5, np.float32), 20))
    # make the adam moments nonzero so zeroing is observable
    ones = jax.tree_util.tree_map(jnp.ones_like, state.params)
    state = state._replace(adam=state.adam._replace(m=ones, v=ones))

    new_pts = rng.normal(0, 0.4, (30, 3)).astype(np.float32) + 5.0
    new_cols = np.full((30, 3), 0.7, np.float32)
    new_state, n_reseeded, slots = reseed_dead_slots(state, new_pts, new_cols, opacity_thresh=0.005)

    assert n_reseeded == 8  # all dead capacity refilled (points were plentiful)
    np.testing.assert_array_equal(slots, np.arange(12, 20))  # the refilled rows
    assert new_state.params.n == 20  # shapes untouched
    means = np.asarray(new_state.params.means)
    np.testing.assert_allclose(means[:12], pts0, atol=0)  # live rows untouched
    assert (np.abs(means[12:]).max(axis=1) > 3.0).all()  # dead rows now near +5
    opac = 1.0 / (1.0 + np.exp(-np.asarray(new_state.params.opacity_logit)))
    assert (opac[12:] > 0.05).all()  # reborn, not dead
    m = np.asarray(new_state.adam.m.means)
    assert (m[:12] == 1.0).all() and (m[12:] == 0.0).all()  # newborn moments zeroed


def test_reseed_with_no_dead_slots_is_identity():
    state = init_state(_random_params(16))
    new_state, n, slots = reseed_dead_slots(state, np.zeros((5, 3), np.float32), np.zeros((5, 3), np.float32))
    assert n == 0 and slots.size == 0
    np.testing.assert_array_equal(
        np.asarray(new_state.params.means), np.asarray(state.params.means)
    )


# ----------------------------------------------------------- temporal store
def test_temporal_store_keyframe_delta_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    frames = []
    g = _random_params(40, seed=3)
    for t in range(5):
        g = g._replace(means=g.means + jnp.asarray(rng.normal(0, 0.01, (40, 3)).astype(np.float32)))
        frames.append(jax.tree_util.tree_map(np.asarray, g))

    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=2)
    for t, f in enumerate(frames):
        store.append(t, f)
    st = store.stats()
    assert store.timesteps() == [0, 1, 2, 3, 4]
    assert st["keyframes"] == 3 and st["delta_frames"] == 2  # every 2nd frame is a key

    for t, ref in enumerate(frames):
        got = store.load(t)
        for name in G.GaussianModel._fields:
            a, b = np.asarray(getattr(got, name)), np.asarray(getattr(ref, name))
            # keyframes restore exactly; delta frames are int16-quantized so
            # they land within one quantum of the true value (no drift:
            # deltas chain against the reconstructed previous frame)
            tol = 1e-7 if t % 2 == 0 else 2e-3
            np.testing.assert_allclose(a, b, atol=tol, err_msg=f"t={t} {name}")


def test_temporal_store_exact_rows_survive_reseed_jump(tmp_path):
    """A reseeded dead slot jumps its mean from the 1e6 sentinel into the
    scene — six orders of magnitude above the training deltas. Jump rows are
    stored exactly; the shared quantization scale must stay tight for the
    smooth rows instead of being poisoned by the jump."""
    g0 = _random_params(32, seed=7)
    g0 = g0._replace(means=g0.means.at[24:].set(1.0e6))  # dead padding
    rng = np.random.default_rng(8)
    drift = jnp.asarray(rng.normal(0, 0.01, (32, 3)).astype(np.float32))
    g1 = g0._replace(means=g0.means + drift)
    g1 = g1._replace(means=g1.means.at[24:].set(  # reseed: sentinel -> scene
        jnp.asarray(rng.normal(0, 0.4, (8, 3)).astype(np.float32))
    ))

    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=10)
    store.append(0, g0)
    store.append(1, g1)  # delta frame containing the jump
    got = np.asarray(store.load(1).means)
    ref = np.asarray(g1.means)
    np.testing.assert_allclose(got[24:], ref[24:], atol=1e-6)  # jumps exact
    np.testing.assert_allclose(got[:24], ref[:24], atol=1e-4)  # smooth rows tight


def test_temporal_store_survives_reopen(tmp_path):
    g = _random_params(24, seed=4)
    d = str(tmp_path / "seq")
    store = TemporalCheckpointStore(d, keyframe_interval=3)
    store.append(0, g)
    store.append(1, g._replace(means=g.means + 0.01))
    store.close()  # async writer: make the sequence durable before reopening

    reopened = TemporalCheckpointStore(d, keyframe_interval=7)
    assert reopened.keyframe_interval == 3  # the on-disk sequence owns its cadence
    assert reopened.timesteps() == [0, 1]
    reopened.append(2, g._replace(means=g.means + 0.02))
    got = reopened.load(2)
    np.testing.assert_allclose(
        np.asarray(got.means), np.asarray(g.means) + 0.02, atol=2e-3
    )
    with pytest.raises(AssertionError):
        reopened.append(2, g)  # timesteps must be strictly increasing


def test_temporal_store_changed_slots_from_delta_encoding(tmp_path):
    """The delta encoding already knows which slots an update rewrote:
    ``changed_slots`` recovers exactly the perturbed rows from a delta frame
    and answers None (unknown) for keyframes."""
    import jax.numpy as jnp

    g = _random_params(32, seed=12)
    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=10)
    store.append(0, g)
    means2 = np.asarray(g.means).copy()
    means2[[3, 7]] += 0.05
    store.append(1, g._replace(means=jnp.asarray(means2)))
    assert store.changed_slots(0) is None  # keyframe: no change set exists
    np.testing.assert_array_equal(store.changed_slots(1), [3, 7])


def test_replay_live_uses_changed_slots_for_partial_invalidation(tmp_path):
    """Post hoc live replay: stored deltas drive world-space invalidation of
    ONE serving slot — after the first pose registers, bounded updates drop
    tile rows, not whole frames, and served frames track the new model."""
    from repro.insitu import replay_live

    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=10)
    g = _random_params(128, seed=5)
    store.append(0, g)
    means = np.asarray(g.means)
    for t in (1, 2):
        moved = means.copy()
        moved[:4] += np.float32(0.05 * t)  # a bounded 4-slot update
        store.append(t, g._replace(means=jnp.asarray(moved)))

    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    server = build_timeline_server(
        store, cfg, timesteps=[0], n_levels=1, max_batch=2, cache_capacity=64
    )
    events = []
    server.add_invalidation_listener(lambda ts, rows: events.append(rows))
    cam = make_cam(H, W)
    frames = [server.submit(cam, timestep=0).result()]  # registers the pose
    replay_live(
        store, server, timesteps=[1, 2], serve_timestep=0,
        on_timestep=lambda t: frames.append(server.submit(cam, timestep=0).result()),
    )
    assert len(frames) == 3  # initial + one per replayed delta timestep
    assert np.abs(frames[2] - frames[0]).max() > 1e-4  # updates visible
    # the delta timesteps invalidated row sets, never the whole frame
    assert len(events) == 2 and all(rows is not None for rows in events)
    # ground truth: the final frame equals a fresh full render of t=2
    ref_server = build_timeline_server(store, cfg, timesteps=[2], n_levels=1, max_batch=2)
    np.testing.assert_array_equal(frames[2], ref_server.submit(cam, timestep=2).result())


# ------------------------------------------------------- time-scrub serving
def test_timeline_server_scrubs_distinct_cached_frames(tmp_path):
    # store -> timeline server: the post hoc time-scrubbing path end-to-end
    store = TemporalCheckpointStore(str(tmp_path / "seq"), keyframe_interval=2)
    for t in range(3):
        store.append(t, _random_params(128, seed=5, shift=0.15 * t))
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    server = build_timeline_server(store, cfg, n_levels=2, max_batch=2, cache_capacity=64)
    assert server.timesteps() == [0, 1, 2]

    cam = make_cam(H, W)
    frames = scrub(server, cam, [0, 1, 2])
    # same camera, three timesteps -> three distinct frames
    assert set(frames) == {0, 1, 2}
    for t in (0, 1):
        assert np.abs(frames[t] - frames[t + 1]).max() > 1e-4
    # replaying the scrub is pure cache hits: no new renders
    calls = server.report()["render"]["calls"]
    frames2 = scrub(server, cam, [0, 1, 2])
    rep = server.report()
    assert rep["render"]["calls"] == calls
    assert rep["cache"]["hits"] >= 3
    for t in (0, 1, 2):
        np.testing.assert_array_equal(frames[t], frames2[t])
    assert rep["timeline"]["requests_per_timestep"] == {0: 2, 1: 2, 2: 2}


def test_add_timestep_replacement_invalidates_cached_frames():
    cfg = GSConfig(img_h=H, img_w=W, k_per_tile=64)
    server = RenderServer(_random_params(128, seed=9), cfg, n_levels=1, max_batch=2, cache_capacity=64)
    cam = make_cam(H, W)
    old_frame = server.submit(cam).result()
    server.add_timestep(0, _random_params(128, seed=9, shift=0.5))  # replace the model
    fut2 = server.submit(cam)  # must MISS the cache and re-render
    assert np.abs(fut2.result() - old_frame).max() > 1e-4
    assert server.report()["render"]["calls"] == 2


def test_timeline_server_rejects_unknown_timestep():
    server = RenderServer(_random_params(64, seed=6), GSConfig(img_h=H, img_w=W, k_per_tile=64), n_levels=1)
    with pytest.raises(KeyError):
        server.submit(make_cam(H, W), timestep=7)


def test_batcher_groups_by_timestep():
    from repro.serve_gs import MicroBatcher, RenderRequest

    b = MicroBatcher(max_batch=4)
    cam = make_cam(H, W)
    r0 = RenderRequest(cam=cam, level=0, timestep=0)
    r1 = RenderRequest(cam=cam, level=0, timestep=1)
    b.submit(r0)
    b.submit(r1)
    mb0 = b.next_batch()
    mb1 = b.next_batch()
    assert mb0.timestep == 0 and mb0.requests == (r0,)
    assert mb1.timestep == 1 and mb1.requests == (r1,)


# --------------------------------------------------- epoch coverage (views)
def test_viewdataset_epoch_covers_every_view():
    from repro.data.views import ViewDataset

    vol = miranda_growth(0.0, res=12)
    data = ViewDataset(vol, n_views=5, img_h=12, img_w=12, cache_dir=None, n_steps_raymarch=8)
    counts = np.zeros(5, int)
    for cams, gt in data.batches(2, steps=5):  # 10 draws = 2 epochs over 5 views
        assert gt.shape == (2, 12, 12, 3)
        # recover view indices by matching view matrices
        all_vm = np.asarray(data.cams.viewmat).reshape(5, -1)
        for vm in np.asarray(cams.viewmat).reshape(2, -1):
            d = np.linalg.norm(all_vm - vm, axis=1)
            counts[int(np.argmin(d))] += 1
    # the old iterator dropped each epoch's leftover views; now every view is
    # sampled exactly once per epoch
    np.testing.assert_array_equal(counts, np.full(5, 2))


# --------------------------------------------- warm start beats cold start
@pytest.mark.slow
def test_warm_start_fewer_steps_and_zero_retraces():
    """After a small timestep perturbation, warm-start reaches the cold-start
    PSNR in strictly fewer optimization steps, with zero re-traces of the
    train step across timesteps."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = GSConfig(
        img_h=48, img_w=48, batch_size=2, k_per_tile=128, max_steps=200,
        densify_from=10**9, opacity_reset_interval=10**9,
    )
    kw = dict(
        cold_steps=80, warm_steps=80, n_views=6, max_points=800,
        n_steps_raymarch=48, init_scale=0.06, eval_every=10, seed=0,
    )
    vol0 = miranda_growth(0.0, res=32)
    vol1 = miranda_growth(0.075, res=32)  # small perturbation

    warm = InsituTrainer(cfg, mesh, **kw)
    warm.start(vol0)
    rep_warm = warm.advance(vol1)
    assert warm.n_traces == 1  # zero re-traces across the two timesteps

    cold = InsituTrainer(cfg, mesh, capacity=warm.capacity, **kw)
    rep_cold = cold.start(vol1)

    target = rep_cold.psnr_after - 0.1
    def steps_to(curve):
        return next((s for s, p in curve if p >= target), None)

    w_steps, c_steps = steps_to(rep_warm.psnr_curve), steps_to(rep_cold.psnr_curve)
    assert w_steps is not None, (target, rep_warm.psnr_curve)
    assert c_steps is not None
    assert w_steps < c_steps, (w_steps, c_steps, target)
