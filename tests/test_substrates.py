"""Substrate-layer tests: pack_pytree, schedules, view pipeline, input specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_arch
from repro.configs.common import SHAPES, lm_batch_specs
from repro.optim.schedules import expon_lr, grendel_lr_scale
from repro.utils.tree import pack_pytree, tree_bytes, tree_count


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_pack_pytree_roundtrip(seed):
    r = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(r.normal(size=(3, 4)).astype(np.float32)),
        "b": [jnp.asarray(r.normal(size=(5,)).astype(np.float32)),
              jnp.asarray(r.normal(size=(2, 2, 2)).astype(np.float32))],
    }
    vec, unpack = pack_pytree(tree)
    assert vec.shape == (3 * 4 + 5 + 8,)
    back = unpack(vec)
    for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_tree_accounting():
    tree = {"x": jnp.zeros((4, 4), jnp.bfloat16), "y": jnp.zeros((10,), jnp.float32)}
    assert tree_count(tree) == 26
    assert tree_bytes(tree) == 16 * 2 + 40


def test_expon_lr_endpoints():
    lr0 = float(expon_lr(0, lr_init=1e-3, lr_final=1e-5, max_steps=100))
    lr1 = float(expon_lr(100, lr_init=1e-3, lr_final=1e-5, max_steps=100))
    assert abs(lr0 - 1e-3) < 1e-9 and abs(lr1 - 1e-5) < 1e-9


def test_grendel_scale():
    assert grendel_lr_scale(1) == 1.0
    assert abs(grendel_lr_scale(16) - 4.0) < 1e-12


def test_view_dataset_cache(tmp_path):
    from repro.data.views import ViewDataset
    from repro.volume import kingsnake_like

    vol = kingsnake_like(res=24)
    d1 = ViewDataset(vol, n_views=3, img_h=16, img_w=16, cache_dir=str(tmp_path), n_steps_raymarch=16)
    d2 = ViewDataset(vol, n_views=3, img_h=16, img_w=16, cache_dir=str(tmp_path), n_steps_raymarch=16)
    np.testing.assert_array_equal(d1.gt, d2.gt)  # second load hits the cache
    batches = list(d1.batches(2, steps=3))
    assert len(batches) == 3 and batches[0][1].shape == (2, 16, 16, 3)


def test_input_specs_all_archs_all_shapes():
    """Every (arch x shape) produces well-formed ShapeDtypeStruct inputs."""
    for aid in ARCH_IDS:
        cfg = get_arch(aid).config()
        for name, shape in SHAPES.items():
            if shape.kind == "decode":
                continue  # decode specs need eval_shape of caches: covered in dry-run
            batch = lm_batch_specs(cfg, shape)
            for leaf in jax.tree_util.tree_leaves(batch):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
                assert leaf.shape[0] == shape.global_batch


def test_orbit_camera_geometry():
    from repro.volume.cameras import camera_slice, orbit_cameras

    cams = orbit_cameras(8, img_h=32, img_w=32, radius=2.5)
    for i in range(8):
        c = camera_slice(cams, i)
        # camera position sits on the radius-2.5 sphere, looks at the origin
        np.testing.assert_allclose(float(jnp.linalg.norm(c.campos)), 2.5, rtol=1e-5)
        fwd = np.asarray(c.viewmat[:3, :3])[2]  # third row = view dir
        to_origin = -np.asarray(c.campos)
        to_origin /= np.linalg.norm(to_origin)
        np.testing.assert_allclose(fwd, to_origin, atol=1e-5)
